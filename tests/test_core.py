"""Graph IR, strategies, scheduler, partitioning, and simulator
behaviour."""

import itertools

import pytest

from repro.core.cost_model import GBE, ULTRASCALE, ZYNQ7020
from repro.core.graph import Graph, Op, resnet18_graph, transformer_graph
from repro.core.partition import (
    even_boundaries,
    layer_boundaries_from_plan,
    layer_costs,
    partition_layers,
    stage_costs,
    stage_depths,
)
from repro.core.scheduler import auto_schedule, predict, rebalance
from repro.core.simulator import graph_service_time, simulate
from repro.core.strategies import STRATEGIES, make_plan


@pytest.fixture(scope="module")
def g():
    return resnet18_graph()


class TestGraph:
    def test_resnet18_macs(self, g):
        # ResNet-18 @224 is ~1.8 GMACs
        assert 1.6e9 < g.total_macs < 2.0e9

    def test_resnet18_params(self, g):
        # ~11.7M params, int8
        assert 10e6 < g.total_param_bytes < 13e6

    def test_topological(self, g):
        seen = set()
        for op in g:
            assert all(d in seen for d in op.deps)
            seen.add(op.name)

    def test_json_roundtrip(self, g):
        g2 = Graph.from_json(g.to_json())
        assert [o.name for o in g2] == [o.name for o in g]
        assert g2.total_macs == g.total_macs

    def test_bottlenecks_sorted(self, g):
        b = g.bottlenecks(5)
        assert all(b[i].macs >= b[i + 1].macs for i in range(4))

    def test_cut_segments_partition(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=10, deadline=None)
        @given(st.integers(min_value=1, max_value=16))
        def check(k):
            graph = resnet18_graph()
            segs = graph.cut_segments(k)
            flat = [op.name for seg in segs for op in seg]
            assert flat == [op.name for op in graph.ops]  # exact cover, in order
            assert 1 <= len(segs) <= k

        check()

    def test_cut_balance(self, g):
        segs = g.cut_segments(4)
        costs = g.segment_macs(segs)
        assert max(costs) < 0.6 * g.total_macs  # no degenerate giant stage

    def test_transformer_graph(self):
        tg = transformer_graph(
            "t", num_layers=4, d_model=64, num_heads=4, kv_heads=2,
            d_ff=128, vocab=1000, seq_len=128,
        )
        assert len(tg) == 4 * 2 + 2
        assert tg.total_macs > 0

    def test_moe_graph_bottleneck(self):
        tg = transformer_graph(
            "m", num_layers=2, d_model=64, num_heads=4, kv_heads=4,
            d_ff=256, vocab=1000, seq_len=128, moe_experts=8, moe_top_k=2,
        )
        assert tg.bottlenecks(1)[0].kind in ("moe_ffn", "dense")


class TestStrategies:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("n", [1, 2, 5, 12])
    def test_plans_validate(self, g, strategy, n):
        plan = make_plan(g, strategy, n)
        plan.validate(g)  # raises on inconsistency

    def test_all_ops_assigned(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=20, deadline=None)
        @given(st.sampled_from(STRATEGIES), st.integers(min_value=1, max_value=12))
        def check(strategy, n):
            graph = resnet18_graph()
            plan = make_plan(graph, strategy, n)
            assert set(plan.assignment) == {op.name for op in graph.ops}
            for op in graph.ops:
                k = plan.way_split(op)
                assert 1 <= k <= max(op.divisible, 1)

        check()

    def test_fused_widths_proportional(self, g):
        plan = make_plan(g, "fused", 12)
        widths = [len(s.nodes) for s in plan.stages]
        assert sum(widths) == 12
        assert all(w >= 1 for w in widths)


def _brute_force_minmax(costs, stages, weights=None):
    """Exhaustive min-max over all contiguous partitions (small n)."""
    n = len(costs)
    rates = weights or [1.0] * stages
    best = float("inf")
    for cuts in itertools.combinations(range(1, n), stages - 1):
        bounds = (0,) + cuts + (n,)
        cost = max(
            sum(costs[a:b]) / r for a, b, r in zip(bounds, bounds[1:], rates)
        )
        best = min(best, cost)
    return best


class TestPartition:
    def test_hand_computable_optimum(self):
        # [4,1,1,1,1,4] into 3 stages: isolate the heavy ends, middle
        # stage takes all four light layers -> max stage cost 4
        bounds = partition_layers([4, 1, 1, 1, 1, 4], 3)
        assert bounds == (0, 1, 5, 6)
        assert stage_costs([4, 1, 1, 1, 1, 4], bounds) == (4, 4, 4)

    @pytest.mark.parametrize("costs", [
        [1, 1, 1, 1, 1, 1, 1, 1],
        [8, 1, 1, 1, 1, 1, 1, 1],
        [1, 2, 3, 4, 5, 6, 7, 8],
        [5, 1, 5, 1, 5, 1, 5, 1],
    ])
    @pytest.mark.parametrize("stages", [2, 3, 4])
    def test_dp_matches_brute_force(self, costs, stages):
        bounds = partition_layers(costs, stages)
        got = max(stage_costs(costs, bounds))
        assert got == pytest.approx(_brute_force_minmax(costs, stages))

    def test_stage_weights_shrink_slow_stage(self):
        # a half-speed stage 0 receives about half the layers
        bounds = partition_layers([1.0] * 12, 4,
                                  stage_weights=[0.5, 1.0, 1.0, 1.0])
        depths = stage_depths(bounds)
        assert depths[0] < max(depths[1:])
        # weighted DP matches the weighted brute force
        got = max(
            s / r for s, r in zip(stage_costs([1.0] * 12, bounds),
                                  [0.5, 1.0, 1.0, 1.0])
        )
        assert got == pytest.approx(
            _brute_force_minmax([1.0] * 12, 4, [0.5, 1.0, 1.0, 1.0])
        )

    def test_even_boundaries_near_even(self):
        assert even_boundaries(8, 4) == (0, 2, 4, 6, 8)
        assert set(stage_depths(even_boundaries(10, 4))) == {2, 3}

    def test_errors(self):
        with pytest.raises(ValueError):
            partition_layers([1, 2], 3)  # more stages than layers
        with pytest.raises(ValueError):
            partition_layers([1, 2, 3], 0)
        with pytest.raises(ValueError):
            stage_depths((0, 2, 2, 4))  # empty stage

    def test_layer_costs_from_transformer_graph(self):
        tg = transformer_graph(
            "t", num_layers=4, d_model=64, num_heads=4, kv_heads=2,
            d_ff=128, vocab=1000, seq_len=128,
        )
        costs = layer_costs(tg)
        assert len(costs) == 4
        assert all(c > 0 for c in costs)
        # book-end ops excluded: per-layer costs are uniform here
        assert max(costs) == pytest.approx(min(costs))

    def test_boundaries_from_plan_roundtrip(self):
        tg = transformer_graph(
            "t", num_layers=8, d_model=64, num_heads=4, kv_heads=2,
            d_ff=128, vocab=1000, seq_len=128,
        )
        plan = make_plan(tg, "pipeline", 4)
        bounds = layer_boundaries_from_plan(plan, 8)
        assert bounds is not None
        assert bounds[0] == 0 and bounds[-1] == 8
        assert stage_depths(bounds)  # non-empty, increasing

    def test_plan_num_layers(self):
        from repro.core.partition import plan_num_layers

        tg = transformer_graph(
            "t", num_layers=8, d_model=64, num_heads=4, kv_heads=2,
            d_ff=128, vocab=1000, seq_len=128,
        )
        assert plan_num_layers(make_plan(tg, "pipeline", 4)) == 8
        g2 = Graph("g2", [Op("a", "dense", 1, 1, 1, 0),
                          Op("b", "dense", 1, 1, 1, 0, deps=("a",))])
        assert plan_num_layers(make_plan(g2, "pipeline", 2)) is None
        # resnet's layer{stage}.{block} names match the pattern but skip
        # layer0, so boundary recovery must reject them downstream
        from repro.core.partition import layer_boundaries_from_plan
        rplan = make_plan(resnet18_graph(), "pipeline", 4)
        n = plan_num_layers(rplan)
        assert n is None or layer_boundaries_from_plan(rplan, n) is None

    def test_rebalance_emits_uneven_boundaries(self):
        """Planner->runtime loop: skewed node rates re-cut the pipeline
        so the slow node's stage is shortest, and the cuts survive as
        layer boundaries for the runtime."""
        tg = transformer_graph(
            "t", num_layers=8, d_model=64, num_heads=4, kv_heads=2,
            d_ff=128, vocab=1000, seq_len=128,
        )
        plan = make_plan(tg, "pipeline", 4)
        re = rebalance(tg, plan, {0: 0.25, 1: 1.0, 2: 1.0, 3: 1.0})
        bounds = layer_boundaries_from_plan(re, 8)
        assert bounds is not None
        depths = stage_depths(bounds)
        assert depths[0] < max(depths[1:])  # slow node -> short stage

    def test_tune_microbatches_divides_batch(self):
        from repro.core.autotune import tune_microbatches

        for sched in ("gpipe", "1f1b"):
            m = tune_microbatches(4, 48, sched)
            assert 48 % m == 0 and 1 <= m <= 48
            # the bubble target must not degenerate to one-sample
            # microbatches (bubble fraction decays monotonically, so
            # "closest to optimal" would always pick the max divisor)
            assert m < 48
        # one stage has no bubble: smallest microbatch count wins
        assert tune_microbatches(1, 64) == 1
        # small batch: no divisor meets the target — fall back to the
        # smallest m that fills the pipe, NOT 1-sample microbatches
        assert tune_microbatches(4, 8) == 4

    def test_bubble_oracle_is_planner_side(self):
        # pure schedule arithmetic importable without the JAX runtime
        from repro.core.partition import pipeline_bubble_counts

        assert pipeline_bubble_counts(4, 8, "forward") == (11, 32, 12)

    def test_pipeline_boundaries_hybrid_group_units(self):
        """attn_every hybrids cut at GROUP granularity: the launcher
        recipe must emit boundaries in the runtime's units (groups),
        not raw layers."""
        from repro.configs.base import get_config
        from repro.core.placement import pipeline_boundaries

        cfg = get_config("zamba2_2p7b").scaled_down(num_layers=8,
                                                    attn_every=2)
        bounds = pipeline_boundaries(cfg, 64, 2)
        assert bounds[0] == 0 and bounds[-1] == 4  # 4 groups, not 8 layers
        dense = get_config("qwen3_0p6b").scaled_down(num_layers=8)
        assert pipeline_boundaries(dense, 64, 2)[-1] == 8


class TestSimulator:
    def test_single_node_anchor(self, g):
        # calibrated to the paper's 27.34 ms within 10%
        r = simulate(g, make_plan(g, "scatter_gather", 1), ZYNQ7020)
        assert abs(r.avg_ms_per_image - 27.34) / 27.34 < 0.10

    def test_ultrascale_anchor(self, g):
        r = simulate(g, make_plan(g, "scatter_gather", 1), ULTRASCALE)
        assert abs(r.avg_ms_per_image - 25.15) / 25.15 < 0.10

    def test_scatter_gather_scales(self, g):
        t1 = simulate(g, make_plan(g, "scatter_gather", 1), ZYNQ7020).avg_ms_per_image
        t12 = simulate(g, make_plan(g, "scatter_gather", 12), ZYNQ7020).avg_ms_per_image
        assert t12 < t1 / 8  # near-linear

    def test_ai_core_small_n_penalty(self, g):
        """The paper's key observation: AI-core assignment is WORSE than
        a single node at N=2 (network overhead), best at N=12."""
        t1 = simulate(g, make_plan(g, "ai_core_assignment", 1), ZYNQ7020).avg_ms_per_image
        t2 = simulate(g, make_plan(g, "ai_core_assignment", 2), ZYNQ7020).avg_ms_per_image
        t12 = simulate(g, make_plan(g, "ai_core_assignment", 12), ZYNQ7020).avg_ms_per_image
        assert t2 > t1  # worse than single node
        assert t12 < t1 / 5

    def test_crossover(self, g):
        """Scatter-gather beats AI-core at small N; AI-core wins at 12
        (Fig. 3 crossover around N=7..9)."""
        sg3 = simulate(g, make_plan(g, "scatter_gather", 3), ZYNQ7020).avg_ms_per_image
        ai3 = simulate(g, make_plan(g, "ai_core_assignment", 3), ZYNQ7020).avg_ms_per_image
        assert sg3 < ai3
        sg12 = simulate(g, make_plan(g, "scatter_gather", 12), ZYNQ7020).avg_ms_per_image
        ai12 = simulate(g, make_plan(g, "ai_core_assignment", 12), ZYNQ7020).avg_ms_per_image
        assert ai12 < sg12 * 1.25  # competitive-or-better at full cluster

    def test_energy_accounting(self, g):
        r = simulate(g, make_plan(g, "scatter_gather", 4), ZYNQ7020)
        # 4 boards at 2.2-4.6 W for ~7ms/image -> tens of mJ, < 0.2 J
        assert 0.0 < r.energy_j_per_image < 0.2

    def test_straggler_hurts(self, g):
        plan = make_plan(g, "pipeline", 4)
        base = simulate(g, plan, ZYNQ7020).avg_ms_per_image
        slow = simulate(g, plan, ZYNQ7020, slowdown={1: 3.0}).avg_ms_per_image
        assert slow > base * 1.3

    def test_rebalance_helps_pipeline(self, g):
        plan = make_plan(g, "pipeline", 4)
        rates = {0: 1.0, 1: 0.33, 2: 1.0, 3: 1.0}
        slow = simulate(g, plan, ZYNQ7020, slowdown={1: 3.0}).avg_ms_per_image
        re = rebalance(g, plan, rates)
        # rebalanced: the slow node gets the lightest stage
        slow2 = simulate(g, re, ZYNQ7020, slowdown={1: 3.0}).avg_ms_per_image
        assert slow2 <= slow * 1.05


class TestScheduler:
    def test_auto_schedule_picks_best(self, g):
        choice = auto_schedule(g, 4, ZYNQ7020)
        assert choice.plan.strategy in STRATEGIES
        assert choice.result.avg_ms_per_image == min(choice.alternatives.values())

    def test_predict_is_finite(self, g):
        for s in STRATEGIES:
            assert 0 < predict(g, s, 6, ZYNQ7020) < 1.0

    def test_reconfigurability_story(self, g):
        """The winner flips with cluster size — the reason the cluster is
        reconfigurable at all."""
        small = auto_schedule(g, 2, ZYNQ7020, strategies=("scatter_gather", "ai_core_assignment"))
        big = auto_schedule(g, 12, ZYNQ7020, strategies=("scatter_gather", "ai_core_assignment"))
        assert small.plan.strategy == "scatter_gather"
        # at N=2 AI-core is FAR worse; by N=12 it has closed the gap
        # completely (paper: it wins outright from N~7)
        gap2 = small.alternatives["ai_core_assignment"] / small.alternatives["scatter_gather"]
        gap12 = big.alternatives["ai_core_assignment"] / big.alternatives["scatter_gather"]
        assert gap2 > 2.0
        assert gap12 < 1.05
