"""Batched autoregressive serving example.

Loads a reduced-config model, prefills a batch of prompts (chunked
prefill path), then decodes tokens step by step with the KV cache —
the CPU-scale version of the decode_32k dry-run cells.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as tf
from repro.serve.step import make_prefill_step, make_serve_step

cfg = get_config("qwen3_0p6b").scaled_down(num_layers=4, d_model=192, vocab=2048)
key = jax.random.PRNGKey(0)
params = tf.init(key, cfg, jnp.float32)

BATCH, PROMPT, NEW, MAXLEN = 4, 48, 24, 128
prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab)

prefill = jax.jit(make_prefill_step(cfg, chunk=16))
decode = jax.jit(make_serve_step(cfg))

caches = tf.init_caches(cfg, BATCH, MAXLEN, jnp.float32)
t0 = time.time()
tok, caches = prefill(params, prompts, caches)
tok = tok[:, None]
t_prefill = time.time() - t0

out = [tok]
t0 = time.time()
for _ in range(NEW - 1):
    tok, caches = decode(params, tok, caches)
    out.append(tok)
jax.block_until_ready(tok)
t_decode = time.time() - t0

gen = jnp.concatenate(out, axis=1)
print(f"prefill  : {BATCH} prompts x {PROMPT} tokens in {t_prefill*1e3:.0f} ms "
      f"(chunked, 16-token chunks)")
print(f"decode   : {NEW} steps x {BATCH} seqs in {t_decode*1e3:.0f} ms "
      f"({BATCH*NEW/t_decode:.0f} tok/s on 1 CPU core)")
print(f"generated shape: {gen.shape}; all ids < vocab: "
      f"{bool(jnp.all(gen < cfg.vocab))}")
assert gen.shape == (BATCH, NEW)
