"""Quickstart: the paper's contribution in 60 seconds.

1. Build the ResNet-18 computation graph (the paper's workload).
2. Ask the scheduler for the best strategy at several cluster sizes —
   watch the winner flip, which is the reason the cluster is
   *reconfigurable*.
3. Simulate the chosen plans and print latency + energy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.cost_model import ZYNQ7020
from repro.core.graph import resnet18_graph
from repro.core.scheduler import auto_schedule

g = resnet18_graph()
print(f"workload: {g.name}  ({g.total_macs/1e9:.2f} GMACs, "
      f"{g.total_param_bytes/1e6:.1f} MB int8 weights, {len(g)} ops)\n")

for n in (1, 2, 4, 8, 12):
    choice = auto_schedule(g, n, ZYNQ7020)
    alts = ", ".join(f"{s[:7]}={ms:.2f}" for s, ms in choice.alternatives.items())
    print(f"N={n:>2}: best={choice.plan.strategy:<20} "
          f"{choice.result.avg_ms_per_image:6.2f} ms/img  "
          f"{choice.result.energy_j_per_image:6.3f} J/img   [{alts}]")

print("\nThe winner flips with cluster size — scatter-gather at small N, "
      "operator splitting once the network stops being the bottleneck. "
      "That crossover is the paper's Fig. 3, and the scheduler exploits it "
      "automatically.")
