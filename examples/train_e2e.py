"""End-to-end training driver (the deliverable-b e2e example).

Trains a reduced qwen3-family LM (~3M params — CPU-sized; pass --big for
the 0.6B published config if you have a pod) for a few hundred steps on
the synthetic pipeline with: grad accumulation, async checkpointing +
restore-on-restart, straggler monitoring hooks, and loss reporting.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft.checkpoint import AsyncCheckpointer
from repro.ft.straggler import StragglerMonitor
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen3_0p6b")
    if not args.big:
        cfg = cfg.scaled_down(num_layers=4, d_model=192, vocab=2048)
    print(f"model: {cfg.name} ({'full' if args.big else 'reduced'}), "
          f"layers={cfg.num_layers} d={cfg.d_model} vocab={cfg.vocab}")

    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt, grad_accum=2, remat=True))
    ckpt = AsyncCheckpointer(args.ckpt, keep=2)

    state = init_state(jax.random.PRNGKey(0), cfg, jnp.float32)
    restored, at = ckpt.restore_latest(state)
    start = 0
    if restored is not None:
        state, start = restored, at
        print(f"resumed from checkpoint at step {start}")

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    pf = Prefetcher(data, start_step=start)
    mon = StragglerMonitor()
    losses = []
    t0 = time.time()
    try:
        for step in range(start, args.steps):
            t_step = time.time()
            state, metrics = step_fn(state, pf.next())
            mon.record(0, time.time() - t_step)  # host 0 self-report
            losses.append(float(metrics["loss"]))
            if (step + 1) % 25 == 0:
                ckpt.save(state, step + 1)
                rep = mon.report()
                print(f"step {step+1:>4}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"stragglers={rep.stragglers}")
    finally:
        pf.close()
        ckpt.wait()
    dt = time.time() - t0
    print(f"\n{args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps-start)/dt:.2f} steps/s)")
    k = max(len(losses) // 10, 1)
    print(f"loss: first-{k}-avg {sum(losses[:k])/k:.4f} -> "
          f"last-{k}-avg {sum(losses[-k:])/k:.4f}")
    assert sum(losses[-k:]) < sum(losses[:k]), "did not learn"
    print("loss decreased; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
