"""Quantized serving on the VTA datapath (int8 x int8 -> int32).

Demonstrates the Pallas kernel path end to end: a small MLP classifier is
quantized to int8 and served via the fused GEMM+dequant kernel — the TPU
analogue of deploying a model on the paper's FPGA cluster.  Outputs are
compared against the f32 reference to show quantization error stays
small.

Run:  PYTHONPATH=src python examples/vta_serving.py
"""

import jax
import jax.numpy as jnp

from repro.kernels import ops

key = jax.random.PRNGKey(0)
k1, k2, k3, k4 = jax.random.split(key, 4)

# a small 2-layer MLP "model" with pretend-trained weights
d_in, d_h, d_out = 256, 512, 10
w1 = jax.random.normal(k1, (d_in, d_h)) * 0.05
w2 = jax.random.normal(k2, (d_h, d_out)) * 0.05
x = jax.random.normal(k3, (32, d_in))  # a batch of requests


def f32_model(x):
    h = jax.nn.relu(x @ w1)
    return h @ w2


# --- quantize (symmetric, per-tensor activations / per-channel weights)
sx = float(jnp.max(jnp.abs(x))) / 127.0
s1 = jnp.max(jnp.abs(w1), axis=0) / 127.0
s2 = jnp.max(jnp.abs(w2), axis=0) / 127.0
xq = ops.quantize(x, sx)
w1q = ops.quantize(w1, s1[None, :])
w2q = ops.quantize(w2, s2[None, :])


def vta_model(xq):
    # layer 1: int8 GEMM + f32 dequant epilogue, relu, requantize
    h = ops.dense_int8(xq, w1q, s1 * sx, interpret=True)
    h = jax.nn.relu(h)
    sh = float(jnp.max(jnp.abs(h))) / 127.0
    hq = ops.quantize(h, sh)
    return ops.dense_int8(hq, w2q, s2 * sh, interpret=True)


ref = f32_model(x)
got = vta_model(xq)
err = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
agree = float(jnp.mean((jnp.argmax(got, -1) == jnp.argmax(ref, -1)).astype(jnp.float32)))

print(f"f32 vs int8-VTA relative error : {err:.3%}")
print(f"top-1 agreement on 32 requests : {agree:.0%}")
assert agree >= 0.9, "quantized serving diverged"
print("served on the VTA GEMM+dequant kernel (interpret mode on CPU; "
      "the same pallas_call targets the 128x128 MXU on TPU).")
